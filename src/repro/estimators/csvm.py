"""Cascade SVM on ds-arrays (paper §6's target workload).

The cascade (Graf et al. 2005, the algorithm dislib ships as CSVM): the
data is partitioned row-wise, each partition trains an SVM, and the
surviving support vectors merge pairwise up a reduction tree until one SV
set remains; that set is fed back into every partition and the cascade
repeats until the global model stops improving.  The structure maps onto
ds-arrays exactly like the paper's task graphs:

* **partitioning** — each level-0 chunk is a block-aligned row slice of the
  one stacked tensor; for BCOO-blocked inputs that is a pure batch-dim
  slice of the stacked BCOO (``core.sparse.aligned_slice_sparse``) — the
  data matrix is NEVER densified on the way in (no ``bcoo_todense``,
  jaxpr-asserted in ``tests/test_estimators.py``);
* **per-node solves** — each node's (small) training set is its rows in the
  model's dense form (``core.sparse.rows_to_dense``: an O(nnz) host
  scatter of the stored entries, the same (s, m) basis libsvm's kernel
  cache materializes) and the dual solves by jitted projected gradient
  ascent with the bias folded into an augmented kernel ``K + 1``;
* **the recorded hot loop** — every cascade iteration evaluates the global
  kernel block ``K(X, SV) = X @ SVᵀ`` for the feedback/convergence check
  through ONE lazy plan: the SV panel is padded to the static ``sv_cap``
  capacity, so each iteration re-records a structurally identical DAG and
  iterations 2..N skip the optimizer (``plan._OPT_CACHE``) and XLA
  (``plan._CACHE``) entirely — regression-tested ``opt_runs == 1`` across a
  5-iteration fit.  For BCOO inputs the plan's GEMM is one sparse-lhs
  ``bcoo_dot_general`` (nnz-proportional — the reason PR 4 built the
  sparse backend for this workload); RBF turns the same product into
  ``exp(-γ(‖x‖² − 2·X·SVᵀ + ‖sv‖²))`` with the row norms ``‖x‖²`` computed
  once, sparse-natively, before the loop.

Cost laws: ``costmodel.csvm_kernel_{flops,hbm_bytes}`` and
``costmodel.csvm_cascade_fit_flops``; measured in
``benchmarks/bench_estimators.py``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocking import ceil_div
from repro.core.dsarray import DsArray, from_array
from repro.core import sparse as sparse_mod
from repro.estimators.base import BaseClassifier, _FitCheckpoint, \
    _fire, _iter_span

_SV_EPS = 1e-6           # dual weight below which a vector is not an SV


@functools.partial(jax.jit, static_argnames=("kernel", "iters"))
def _solve_dual(b, y, mult, gamma, c, kernel: str, iters: int):
    """Dual SVM by projected gradient ascent on the augmented kernel.

    max  Σα − ½ αᵀ Q α,  0 ≤ α ≤ C·mult,   Q = (y yᵀ) ∘ (K + 1)

    The ``+ 1`` embeds the bias as a constant feature, so the equality
    constraint of the classic dual disappears and the box projection is
    exact; the bias recovers as ``b = Σ α y``.  The step size is 1/λmax(Q)
    from a short power iteration, which makes the ascent a contraction.
    ``mult`` is the per-candidate multiplicity: 0 masks padded/duplicate
    slots out of the model, and a genuine sample stored k times collapses
    to one slot with box k·C — exactly the dual a standard SVM gives k
    identical rows (shapes stay static either way).
    """
    s = b.shape[0]
    if kernel == "rbf":
        sq = jnp.sum(b * b, axis=1)
        k = jnp.exp(-gamma * jnp.maximum(
            sq[:, None] - 2.0 * (b @ b.T) + sq[None, :], 0.0))
    else:
        k = b @ b.T
    q = (y[:, None] * y[None, :]) * (k + 1.0)
    v = jnp.full((s,), 1.0 / np.sqrt(s), b.dtype)
    for _ in range(12):
        w = q @ v
        v = w / jnp.maximum(jnp.linalg.norm(w), 1e-12)
    eta = 1.0 / jnp.maximum(v @ (q @ v), 1e-6)
    box = c * mult

    def body(_, a):
        return jnp.clip(a + eta * (1.0 - q @ a), 0.0, box)

    return jax.lax.fori_loop(0, iters, body, jnp.zeros((s,), b.dtype))


def _chunk_bounds(n: int, bn: int, n_chunks: int) -> List[Tuple[int, int]]:
    """Block-aligned row ranges covering [0, n): each chunk owns a whole
    number of block rows (so sparse chunks stay batch-dim slices)."""
    gn = max(1, ceil_div(n, bn))
    n_chunks = max(1, min(n_chunks, gn))
    per = ceil_div(gn, n_chunks)
    bounds = []
    for i in range(0, gn, per):
        r0, r1 = i * bn, min((i + per) * bn, n)
        if r1 > r0:
            bounds.append((r0, r1))
    return bounds


@dataclasses.dataclass
class CascadeSVM(BaseClassifier):
    """dislib-style cascade SVM: ``CascadeSVM(...).fit(x, y)`` with ``x`` a
    dense or BCOO-blocked ds-array and binary ``y``.

    ``sv_cap`` is the static support-vector capacity of the model (and of
    every cascade node's output): it makes all fed-back shapes static, which
    is what lets the per-iteration recorded plan hit the structural caches.
    It is also the cascade's approximation knob — like the original cascade
    (which assumes SVs ≪ data), a cap BELOW the problem's true support size
    truncates the dual and accuracy degrades sharply (noisy/overlapping
    classes need large caps; at ``sv_cap ≥`` the sklearn support count the
    solver matches ``SVC``), so size it generously for hard data.
    """

    c: float = 1.0
    kernel: str = "rbf"               # "rbf" | "linear"
    gamma: object = "scale"           # float | "scale" → 1/(m·Var(x)) |
                                      # "auto" → 1/m (sklearn's names)
    cascade_arity: int = 2
    n_chunks: Optional[int] = None    # default: one chunk per block row
    sv_cap: int = 64
    max_iter: int = 5
    tol: float = 1e-3
    solver_iters: int = 300

    sv_: Optional[np.ndarray] = None      # (sv_cap, m) padded SV rows
    sv_y_: Optional[np.ndarray] = None    # (sv_cap,) labels in {-1, 0, +1}
    dual_coef_: Optional[np.ndarray] = None   # (sv_cap,) alpha (0 on pads)
    intercept_: float = 0.0
    gamma_: float = 0.0                   # resolved RBF width
    n_sv_: int = 0
    n_iter_: int = 0
    converged_: bool = False

    # -- per-node solve ------------------------------------------------------
    def _resolve_gamma(self, x: DsArray) -> float:
        """The RBF width as a number.  ``"scale"`` (sklearn's default,
        ``1/(m·Var(x))``) derives the variance from two sparse-native
        whole-array reductions — implicit zeros are real values of the
        distribution, so ``E[x²] − E[x]²`` over all n·m positions is exactly
        right and the bcoo operand never densifies.  The linear kernel
        never reads gamma, so it skips the data passes entirely."""
        n, m = x.shape
        if self.kernel != "rbf":
            return 0.0
        if self.gamma == "auto":
            return 1.0 / m
        if self.gamma == "scale":
            mean = float(np.asarray(x.mean()))
            e2 = float(np.asarray((x * x).sum())) / (n * m)
            var = max(e2 - mean * mean, 1e-12)
            return 1.0 / (m * var)
        return float(self.gamma)

    @staticmethod
    def _dedup(b: np.ndarray, y: np.ndarray, mult: np.ndarray,
               is_data: np.ndarray) -> np.ndarray:
        """Collapse duplicate (row, label) candidates into one slot.

        Two distinct kinds of duplicate reach a node: (a) **copies** —
        feedback puts the global SV set into EVERY level-0 chunk and merges
        concatenate children wholesale, so the same vector arrives k times
        without representing k samples (an un-deduped cascade hands it an
        effective box of k·C and collapses to chance within 3 iterations);
        (b) **genuine repeated samples** in the data, whose combined box
        really is k·C (what a standard SVM gives k identical rows).  Data
        rows precede model copies in every node's layout, so: data-data
        duplicates ACCUMULATE multiplicity onto the first slot, while any
        duplicate involving a model copy zeroes the copy.  Shapes are
        untouched — only ``mult`` changes."""
        mult = mult.copy()
        seen: dict = {}
        for i in np.flatnonzero(mult > 0):
            key = (b[i].tobytes(), float(y[i]))
            j = seen.setdefault(key, i)
            if j != i:
                if is_data[i] and is_data[j]:
                    mult[j] += mult[i]
                mult[i] = 0.0
        return mult

    def _node_solve(self, b: np.ndarray, y: np.ndarray, mult: np.ndarray,
                    is_data: np.ndarray, gamma: float):
        """Solve one cascade node and keep its top ``sv_cap`` support
        vectors, returned PADDED to the static capacity."""
        mult = self._dedup(b, y, mult, is_data)
        alpha = np.asarray(_solve_dual(
            jnp.asarray(b), jnp.asarray(y), jnp.asarray(mult, jnp.float32),
            jnp.float32(gamma), jnp.float32(self.c),
            self.kernel, int(self.solver_iters)))
        order = np.argsort(-alpha)[: self.sv_cap]
        rows = np.zeros((self.sv_cap, b.shape[1]), np.float32)
        yy = np.zeros((self.sv_cap,), np.float32)
        aa = np.zeros((self.sv_cap,), np.float32)
        mm = np.zeros((self.sv_cap,), np.float32)
        k = len(order)
        rows[:k], yy[:k], aa[:k] = b[order], y[order], alpha[order]
        mm[:k] = mult[order]
        keep = aa > _SV_EPS * self.c
        return (rows, np.where(keep, yy, 0.0), np.where(keep, aa, 0.0),
                np.where(keep, mm, 0.0))

    # -- the recorded global kernel block ------------------------------------
    def _kernel_block(self, xl, x: DsArray, sv: np.ndarray,
                      x_sq: Optional[np.ndarray]) -> np.ndarray:
        """``K(X, SV)`` as an (n, sv_cap) host array; the data-side
        contraction ``X @ SVᵀ`` runs as one recorded lazy plan (sparse-lhs
        ``bcoo_dot_general`` for bcoo ``x``, never densifying it) whose
        structure — and therefore optimizer + compile cache entry — is
        identical every cascade iteration."""
        sv_ds = from_array(jnp.asarray(sv.T), (x.block_shape[1], self.sv_cap))
        prod = (xl @ sv_ds).compute()                    # (n, sv_cap)
        km = np.asarray(prod.collect(), np.float32)
        if self.kernel == "rbf":
            sv_sq = (sv * sv).sum(axis=1)
            km = np.exp(-self.gamma_ * np.maximum(
                x_sq[:, None] - 2.0 * km + sv_sq[None, :], 0.0))
        return km

    def _decision_values(self, xl, x: DsArray,
                         x_sq: Optional[np.ndarray]) -> np.ndarray:
        km = self._kernel_block(xl, x, self.sv_, x_sq)
        return km @ (self.dual_coef_ * self.sv_y_) + self.intercept_

    def _decision_host(self, x) -> Tuple[np.ndarray, DsArray]:
        """(decision values on the host, validated x) — shared by
        decision_function and predict so predict does not round-trip the
        margins through a device ds-array it immediately collects."""
        x = self._validate_x(x).ensure_zero_pad()
        return self._decision_values(x.lazy(), x, self._row_sq(x)), x

    def _row_sq(self, x: DsArray) -> Optional[np.ndarray]:
        """Iteration-invariant ‖x‖² row norms for the RBF expansion, via the
        eager sparse-native pair-multiply + bcoo row reduction (dense: one
        fused square+reduce) — computed once, outside the recorded loop."""
        if self.kernel != "rbf":
            return None
        sq = (x * x).sum(axis=1)
        return np.asarray(sq.collect(), np.float32).ravel()

    # -- fit -----------------------------------------------------------------
    def fit(self, x, y, checkpoint_dir: Optional[str] = None,
            resume: Optional[str] = None) -> "CascadeSVM":
        """Fit the cascade.  ``checkpoint_dir`` commits the full
        cross-iteration state (feedback SVs + convergence trackers + fitted
        snapshot) after every outer iteration; ``resume`` restarts from the
        newest committed iteration in that directory — a fit killed at
        cascade iteration k resumed this way is equivalent to the
        uninterrupted fit (the per-chunk solves are deterministic functions
        of (x, y, feedback state))."""
        with self._driver_scope():
            return self._fit(x, y, checkpoint_dir=checkpoint_dir,
                             resume=resume)

    def _fit(self, x, y, checkpoint_dir: Optional[str] = None,
             resume: Optional[str] = None) -> "CascadeSVM":
        if self.kernel not in ("rbf", "linear"):
            raise ValueError(f"unknown kernel {self.kernel!r}")
        x, y_raw = self._validate_fit(x, y)
        x = x.ensure_zero_pad()
        yi = self._encode_labels(y_raw, n_classes=2)
        ypm = (2.0 * yi - 1.0).astype(np.float32)
        n, m = x.shape
        gamma = self.gamma_ = self._resolve_gamma(x)
        bounds = _chunk_bounds(n, x.block_shape[0],
                               self.n_chunks if self.n_chunks else 1 << 30)
        x_sq = self._row_sq(x)
        xl = x.lazy()

        fb_rows = np.zeros((self.sv_cap, m), np.float32)
        fb_y = np.zeros((self.sv_cap,), np.float32)
        fb_mult = np.zeros((self.sv_cap,), np.float32)
        prev_obj = np.inf
        self.converged_ = False
        start_it = 1
        if resume is not None:
            got = _FitCheckpoint(resume, type(self).__name__).load()
            if got is not None:
                it0, st = got
                fb_rows = np.asarray(st["fb_rows"])
                fb_y = np.asarray(st["fb_y"])
                fb_mult = np.asarray(st["fb_mult"])
                prev_obj = float(st["prev_obj"])
                self.sv_ = np.asarray(st["sv"])
                self.sv_y_ = np.asarray(st["sv_y"])
                self.dual_coef_ = np.asarray(st["dual_coef"])
                self.intercept_ = float(st["intercept"])
                self.n_sv_ = int(st["n_sv"])
                self.n_iter_ = int(st["n_iter"])
                self.converged_ = bool(st["converged"])
                if self.converged_:
                    return self
                start_it = it0 + 1
        ckpt = _FitCheckpoint(checkpoint_dir, type(self).__name__) \
            if checkpoint_dir is not None else None
        for it in range(start_it, self.max_iter + 1):
            _fire("fit_iteration", estimator=type(self).__name__,
                  iteration=it)
            with _iter_span(self, it):
                # level 0: every chunk (data, multiplicity 1 each) + the
                # fed-back global SV slot (model copies; static cap).  Each
                # chunk's dense basis is a block-aligned slice of the stacked
                # BCOO (x never densified) scattered on the host per node and
                # released right after its solve — peak driver memory is ONE
                # chunk, not the whole data matrix
                sets = []
                for r0, r1 in bounds:
                    cb = sparse_mod.rows_to_dense(x[r0:r1]).astype(np.float32)
                    cy = ypm[r0:r1]
                    b = np.concatenate([cb, fb_rows])
                    yy = np.concatenate([cy, fb_y])
                    mult = np.concatenate([np.ones(len(cb), np.float32),
                                           fb_mult])
                    is_data = np.concatenate([np.ones(len(cb), bool),
                                              np.zeros(self.sv_cap, bool)])
                    sets.append(self._node_solve(b, yy, mult, is_data, gamma))
                # merge tree: arity-way concats of capped SV sets (all model
                # copies — cross-chunk duplicates collapse without accumulating)
                while len(sets) > 1:
                    nxt = []
                    for i in range(0, len(sets), self.cascade_arity):
                        grp = sets[i: i + self.cascade_arity]
                        if len(grp) == 1:
                            nxt.append(grp[0])
                            continue
                        b = np.concatenate([g[0] for g in grp])
                        yy = np.concatenate([g[1] for g in grp])
                        mult = np.concatenate([g[3] for g in grp])
                        is_data = np.zeros(len(b), bool)
                        nxt.append(self._node_solve(b, yy, mult, is_data, gamma))
                    sets = nxt
                rows, yy, aa, mm = sets[0]
                keep = aa > _SV_EPS * self.c
                self.sv_, self.sv_y_, self.dual_coef_ = rows, yy, aa
                self.intercept_ = float((aa * yy).sum())   # b of the K+1 dual
                self.n_sv_ = int(keep.sum())
                self.n_iter_ = it
                # global convergence: hinge objective over ALL data through the
                # one recorded kernel-block plan (cache-hit after iteration 1)
                dec = self._decision_values(xl, x, x_sq)
                obj = float(np.maximum(0.0, 1.0 - ypm * dec).sum())
                # no convergence verdict until there is a previous objective to
                # compare against (inf <= tol*inf would stop every fit at it=1)
                if np.isfinite(prev_obj) and \
                        abs(prev_obj - obj) <= self.tol * max(1.0, abs(prev_obj)):
                    self.converged_ = True
                else:
                    prev_obj = obj
                    fb_rows, fb_y, fb_mult = rows, yy, mm
                if ckpt is not None:
                    # commit AFTER the state advance, so the newest committed
                    # iteration fully determines every later one
                    ckpt.save(it, {
                        "fb_rows": fb_rows, "fb_y": fb_y, "fb_mult": fb_mult,
                        "prev_obj": float(prev_obj),
                        "sv": self.sv_, "sv_y": self.sv_y_,
                        "dual_coef": self.dual_coef_,
                        "intercept": float(self.intercept_),
                        "n_sv": int(self.n_sv_), "n_iter": int(self.n_iter_),
                        "converged": bool(self.converged_)})
                if self.converged_:
                    break
        return self

    # -- inference -----------------------------------------------------------
    def decision_function(self, x) -> DsArray:
        """Signed margins as a new ``(n, 1)`` ds-array (positive →
        ``classes_[1]``); the kernel block reuses fit's cached plan when the
        input geometry matches."""
        self._check_fitted("sv_")
        with self._driver_scope():
            dec, x = self._decision_host(x)
            return self._labels_ds(dec.astype(np.float32), x)

    def predict(self, x) -> DsArray:
        self._check_fitted("sv_")
        with self._driver_scope():
            dec, x = self._decision_host(x)
            labels = np.where(dec > 0, self.classes_[1], self.classes_[0])
            return self._labels_ds(labels, x)
