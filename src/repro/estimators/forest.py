"""Random forest classifier on ds-arrays, histogram-grown on the stacked
tensor.

dislib's RandomForestClassifier trains each tree on a bootstrap of the
distributed dataset; the TPU-native adaptation here replaces per-node row
partitioning (data-dependent shapes, host recursion) with the
**histogram/level-synchronous** growth scheme of LightGBM-style trees, which
is one dense contraction per level:

* features are quantized ONCE into ``n_bins`` codes block-natively: one
  broadcast compare of the stacked block tensor against per-feature bin
  edges (derived from the block-native ``min``/``max`` column reductions) —
  the codes keep the block layout (rank-3, grid dim leading; the global
  ``(n, m)`` rank-2 form is never built);
* every tree level computes ALL (tree, node, feature, bin, class) histogram
  counts in ONE einsum over the codes — trees ride a leading ``vmap``-style
  batch dim, bootstraps enter as per-(tree, sample) multiplicities drawn
  per block row ("per-block bootstrap": one PyCOMPSs task per block, here
  one fold of the seeded generator per (tree, block-row)).  The einsum
  consumes an explicit (n-ish, m, n_bins) one-hot of the codes — a
  deliberate ``n_bins``× memory-for-simplicity trade at the current test/
  bench scales; the ROADMAP follow-on replaces it with a segment-sum
  histogram over the integer codes at O(n·m) memory;
* splits maximize the Gini-impurity decrease from the cumulative
  histograms; samples route to the next level with one gather per level;
* ``predict`` walks all trees for every row inside a single
  ``apply_along_axis`` call — one nested-vmap launch in block layout whose
  per-row body is the majority vote over trees (block-native vote
  reduction returning the usual ``(n, 1)`` ds-array).

Cost laws: ``costmodel.forest_histogram_passes`` /
``costmodel.forest_level_flops`` (the level contraction reads the code
tensor once per level for the WHOLE forest — naive per-node partitioning
reads it once per node).

BCOO-blocked inputs densify on entry by policy: quantization compares every
position (implicit zeros land in a bin too), which has no index-preserving
sparse form — the op table in ``core.dsarray`` lists the estimator entry
points with their storage behaviour.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsarray import DsArray, apply_along_axis
from repro.estimators.base import BaseClassifier


@functools.partial(jax.jit, static_argnames=("n_bins",))
def _quantize_blocks(blocks, edges, n_bins: int):
    """Bin codes for every element of the stacked tensor: ``sum(x > edge)``
    over the per-feature bin edges laid out in block layout ``(gm, bm,
    n_bins-1)``.  One broadcast compare per edge set, block-parallel."""
    del n_bins
    return (blocks[..., None] > edges[None, :, None, :, :]).sum(-1) \
        .astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_bins", "n_classes"))
def _level_histogram(codes1h, node1h_w, y1h, n_bins: int, n_classes: int):
    """counts[t, node, feature, bin, class] for one level, as ONE einsum
    over the (block-laid-out) samples: g = block row, a = row-in-block."""
    del n_bins, n_classes
    return jnp.einsum("gafB,tgaN,gaC->tNfBC", codes1h, node1h_w, y1h)


@functools.partial(jax.jit, static_argnames=("n_bins",))
def _best_splits(counts, n_bins: int):
    """Per (tree, node): the (feature, bin) split maximizing the Gini
    decrease, from the cumulative histogram.  Returns (feat, bin, gain);
    nodes with no positive gain get the sentinel bin ``n_bins`` (every
    sample routes left, i.e. the node stops splitting)."""
    left = jnp.cumsum(counts, axis=3)                # (t, N, f, B, C)
    total = left[:, :, :, -1:, :]
    right = total - left
    nl = left.sum(-1)                                # (t, N, f, B)
    nr = right.sum(-1)
    gl = nl - (left ** 2).sum(-1) / jnp.maximum(nl, 1.0)    # nl * gini_left
    gr = nr - (right ** 2).sum(-1) / jnp.maximum(nr, 1.0)
    nt = total.sum(-1)                               # (t, N, 1, 1) weight
    gp = nt - (total ** 2).sum(-1) / jnp.maximum(nt, 1.0)
    gain = gp - gl - gr                              # (t, N, f, B)
    # a split must send something BOTH ways; bin B-1 sends all left
    gain = jnp.where((nl > 0) & (nr > 0), gain, -jnp.inf)
    t, n_nodes, m, b = gain.shape
    flat = gain.reshape(t, n_nodes, m * b)
    best = jnp.argmax(flat, axis=-1)
    best_gain = jnp.take_along_axis(flat, best[..., None], -1)[..., 0]
    feat = best // b
    sbin = jnp.where(best_gain > 1e-6, best % b, n_bins)   # sentinel: leaf
    return feat.astype(jnp.int32), sbin.astype(jnp.int32), best_gain


@dataclasses.dataclass
class RandomForestClassifier(BaseClassifier):
    """dislib-style forest: ``RandomForestClassifier(...).fit(x, y)``.

    Trees are complete binary trees of ``max_depth`` levels stored as flat
    heap arrays (``feat_[t, node]`` / ``bin_[t, node]`` per level, leaf
    class distribution at the bottom), grown level-synchronously from
    histogram contractions — every array shape is static, so the whole
    fit jits and replays across calls.
    """

    n_estimators: int = 8
    max_depth: int = 6
    n_bins: int = 16
    bootstrap: bool = True
    seed: int = 0

    classes_: Optional[np.ndarray] = None
    edges_: Optional[np.ndarray] = None     # (m, n_bins-1) feature bin edges
    feat_: Optional[np.ndarray] = None      # (t, 2^depth - 1) split features
    bin_: Optional[np.ndarray] = None       # (t, 2^depth - 1) split bins
    leaf_class_: Optional[np.ndarray] = None  # (t, 2^depth) class index
    n_features_in_: int = 0

    # -- fit -----------------------------------------------------------------
    def _bin_edges(self, x: DsArray) -> np.ndarray:
        """Uniform per-feature bin edges between the block-native column
        min/max reductions (paper Fig. 5 column tasks)."""
        lo = np.asarray(x.min(axis=0).collect(), np.float32).ravel()
        hi = np.asarray(x.max(axis=0).collect(), np.float32).ravel()
        span = np.where(hi > lo, hi - lo, 1.0)
        steps = np.arange(1, self.n_bins, dtype=np.float32) / self.n_bins
        return (lo[:, None] + span[:, None] * steps[None, :]).astype(np.float32)

    def _bootstrap_weights(self, gn: int, bn: int, n: int,
                           t: int) -> np.ndarray:
        """(t, gn, bn) sample multiplicities: each (tree, block-row) draws
        its own bootstrap of the block's valid rows from one fold of the
        seeded generator — the per-block task analogue, independent of how
        the grid is later distributed."""
        w = np.zeros((t, gn, bn), np.float32)
        for ti in range(t):
            for g in range(gn):
                rows = min(bn, n - g * bn)
                if rows <= 0:
                    continue
                rng = np.random.default_rng(
                    np.random.SeedSequence([self.seed, ti, g]))
                if self.bootstrap:
                    w[ti, g, :rows] = np.bincount(
                        rng.integers(0, rows, size=rows), minlength=rows)[:rows]
                else:
                    w[ti, g, :rows] = 1.0
        return w

    def fit(self, x, y) -> "RandomForestClassifier":
        with self._driver_scope():
            return self._fit(x, y)

    def _fit(self, x, y) -> "RandomForestClassifier":
        x, y_raw = self._validate_fit(x, y)
        if x.is_sparse:
            x = x.todense()          # quantization bins every position
        x = x.ensure_zero_pad()
        yi = self._encode_labels(y_raw)
        n, m = x.shape
        c = len(self.classes_)
        t, depth, nb = self.n_estimators, self.max_depth, self.n_bins
        self.n_features_in_ = m
        self.edges_ = self._bin_edges(x)

        gn, gm, bn, bm = x.blocks.shape
        # block-laid-out edges -> codes on the stacked tensor -> row-grouped
        # rank-3 (grid dim leading; never the (n, m) rank-2 global form)
        edges_b = np.zeros((gm, bm, nb - 1), np.float32)
        edges_flat = np.full((gm * bm, nb - 1), np.inf, np.float32)
        edges_flat[:m] = self.edges_
        edges_b[:] = edges_flat.reshape(gm, bm, nb - 1)
        codes = _quantize_blocks(x.blocks, jnp.asarray(edges_b), nb)
        codes_rows = codes.transpose(0, 2, 1, 3).reshape(gn, bn, gm * bm)
        codes_rows = codes_rows[:, :, :m]                      # (gn, bn, m)

        w = jnp.asarray(self._bootstrap_weights(gn, bn, n, t))  # (t, gn, bn)
        y_pad = np.zeros((gn * bn,), np.int64)
        y_pad[:n] = yi
        y1h = jax.nn.one_hot(jnp.asarray(y_pad.reshape(gn, bn)), c)
        codes1h = jax.nn.one_hot(codes_rows, nb)               # (gn,bn,m,B)

        node = jnp.zeros((t, gn, bn), jnp.int32)
        feats, bins = [], []
        for level in range(depth):
            node1h_w = jax.nn.one_hot(node, 1 << level) * w[..., None]
            counts = _level_histogram(codes1h, node1h_w, y1h, nb, c)
            feat, sbin, _ = _best_splits(counts, nb)           # (t, 2^level)
            feats.append(np.asarray(feat))
            bins.append(np.asarray(sbin))
            # route: node' = 2*node + (code[sample, feat(node)] > bin(node))
            f_sel = jnp.take_along_axis(
                feat, node.reshape(t, -1), axis=1).reshape(node.shape)
            b_sel = jnp.take_along_axis(
                sbin, node.reshape(t, -1), axis=1).reshape(node.shape)
            # take_along_axis broadcasts the leading dims: the (1, n, m)
            # code tensor is shared across trees, never copied t times
            code_sel = jnp.take_along_axis(
                codes_rows.reshape(1, -1, m),
                f_sel.reshape(t, -1, 1), axis=2).reshape(node.shape)
            node = 2 * node + (code_sel > b_sel)
        # leaves: class distribution per (tree, leaf); empty leaves inherit
        # the global distribution so they never predict an unseen class id
        node1h_w = jax.nn.one_hot(node, 1 << depth) * w[..., None]
        leaf_counts = jnp.einsum("tgaN,gaC->tNC", node1h_w, y1h)
        prior = jax.nn.one_hot(jnp.asarray(yi), c).sum(0) * 1e-6
        self.leaf_class_ = np.asarray(
            jnp.argmax(leaf_counts + prior[None, None, :], axis=-1),
            np.int32)
        self.feat_ = np.concatenate(feats, axis=1)     # heap order per level
        self.bin_ = np.concatenate(bins, axis=1)
        return self

    # -- predict -------------------------------------------------------------
    def predict(self, x) -> DsArray:
        """Majority vote of all trees, block-natively: ONE
        ``apply_along_axis`` nested-vmap launch whose per-row body
        quantizes the row, walks every tree (vmapped) and bin-counts the
        votes — no ``collect()`` of the data."""
        self._check_fitted("feat_")
        with self._driver_scope():
            return self._predict(x)

    def _predict(self, x) -> DsArray:
        x = self._validate_x(x)
        if x.is_sparse:
            x = x.todense()
        t, depth = self.n_estimators, self.max_depth
        c = len(self.classes_)
        edges = jnp.asarray(self.edges_)                       # (m, B-1)
        feat = jnp.asarray(self.feat_)                         # (t, 2^d - 1)
        sbin = jnp.asarray(self.bin_)
        leaf = jnp.asarray(self.leaf_class_)                   # (t, 2^d)
        classes = jnp.asarray(self.classes_)
        level_base = np.cumsum([0] + [1 << d for d in range(depth - 1)])
        level_base = jnp.asarray(level_base, jnp.int32)        # (depth,)

        def one_tree(codes, tf, tb, tl):
            def step(d, nd):
                idx = level_base[d] + nd
                go_right = codes[tf[idx]] > tb[idx]
                return 2 * nd + go_right.astype(jnp.int32)
            nd = jax.lax.fori_loop(0, depth, step, jnp.int32(0))
            return tl[nd]

        def row_vote(row):
            codes = (row[:, None] > edges).sum(-1).astype(jnp.int32)
            votes = jax.vmap(one_tree, in_axes=(None, 0, 0, 0))(
                codes, feat, sbin, leaf)                       # (t,)
            counts = (votes[:, None] ==
                      jnp.arange(c)[None, :]).sum(0)           # (c,)
            return classes[jnp.argmax(counts)].astype(classes.dtype)

        out = apply_along_axis(row_vote, 1, x)                 # (n, 1)
        return out.astype(classes.dtype) if out.dtype != classes.dtype else out
