"""Distributed linear models: LinearRegression / Ridge on ds-arrays.

Fit goes through the **distributed normal equations**: the Gram matrix
``XᵀX`` and moment vector ``Xᵀy`` are recorded as ONE lazy plan —
``x.lazy().T @ x`` folds to the transpose-absorbed GEMM (``matmul_ta``) and
hash-consing shares the ``x`` leaf between the two products — then the
small ``(m+1, m+1)`` system solves host-side.  For BCOO-blocked ``x`` the
sparse operand rides the sparse-lhs ``bcoo_dot_general`` path: ``Xᵀy`` and
the column sums are fully sparse-native, and ``XᵀX`` streams the stored
entries on the left (only the rhs copy takes its dense form — jax has no
sp×sp contraction; same policy as ``core.structural.gram``).  The intercept
is carried as an augmented row/column built from ``x.sum(axis=0)``
(sparse-native), NOT by centering, so sparse inputs stay sparse.

Ill-conditioned tall-skinny inputs: the normal equations square the
condition number, so when ``alpha == 0`` and the Gram's spectrum says
``cond(X) ≳ 1/√eps`` the fit falls back to the **TSQR** factorization
(``algorithms.linalg.tsqr``: vmapped per-block QR + an R-merge reduction
tree) and solves ``R θ = Qᵀ y`` — numerically safe for the f32 block
tensors.  Ridge (``alpha > 0``) regularizes the Gram directly and keeps the
one-plan path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan
from repro.core.dsarray import DsArray, from_array
from repro.estimators.base import BaseRegressor
from repro.resilience.guards import NumericalDivergence, require_finite_host

# cond(X) beyond which the squared-cond normal equations lose f32 accuracy
# (cond(G) = cond(X)² ≳ 1/eps_f32 ≈ 1.7e7): fall back to TSQR
_COND_FALLBACK = 3e3


@dataclasses.dataclass
class LinearRegression(BaseRegressor):
    """Ordinary least squares ``y = x @ coef_ + intercept_`` on ds-arrays.

    ``solver``: ``"auto"`` (normal equations, TSQR fallback when the Gram
    is ill-conditioned and ``alpha == 0``), ``"normal"``, or ``"tsqr"``
    (dense inputs only — QR factors are dense whatever the input).
    """

    fit_intercept: bool = True
    alpha: float = 0.0
    solver: str = "auto"

    coef_: Optional[np.ndarray] = None
    intercept_: float = 0.0
    n_features_in_: int = 0
    solver_used_: str = ""

    def _normal_stats(self, x: DsArray, y: np.ndarray):
        """(XᵀX, Xᵀy, colsums) via one recorded lazy plan: the optimizer
        folds both transposes into ``matmul_ta`` (sparse-native for bcoo)
        and CSE shares the single ``x`` leaf across all three roots."""
        y_ds = from_array(jnp.asarray(y, jnp.float32).reshape(-1, 1),
                          (x.block_shape[0], 1))
        xl = x.lazy()
        g = xl.T @ x
        c = xl.T @ y_ds
        s = xl.sum(axis=0)
        g_ds, c_ds, s_ds = plan.compute_multi(g, c, s)
        gram = np.asarray(g_ds.collect(), np.float64)
        xty = np.asarray(c_ds.collect(), np.float64).ravel()
        colsum = np.asarray(s_ds.collect(), np.float64).ravel()
        return gram, xty, colsum

    def _solve_normal(self, gram, xty, colsum, n, ysum):
        m = gram.shape[0]
        if self.fit_intercept:
            a = np.zeros((m + 1, m + 1))
            a[:m, :m] = gram
            a[:m, m] = colsum
            a[m, :m] = colsum
            a[m, m] = n
            b = np.concatenate([xty, [ysum]])
            reg = np.eye(m + 1) * self.alpha
            reg[m, m] = 0.0                      # never penalize the intercept
        else:
            a, b, reg = gram, xty, np.eye(m) * self.alpha
        try:
            theta = require_finite_host(np.linalg.solve(a + reg, b),
                                        "normal-equations solution")
        except (np.linalg.LinAlgError, NumericalDivergence):
            # rank-deficient Gram (all-zero feature columns are routine in
            # sparse text data): the min-norm lstsq solution, like sklearn
            theta = np.linalg.lstsq(a + reg, b, rcond=None)[0]
        if self.fit_intercept:
            return theta[:m], float(theta[m])
        return theta, 0.0

    def _solve_tsqr(self, x: DsArray, y: np.ndarray):
        """QR path for ill-conditioned tall-skinny inputs: cond(R) ==
        cond(X), no squaring.  The intercept comes from centering (dense
        path only); ``alpha > 0`` solves the REGULARIZED least squares by
        factoring the row-augmented system ``[X; √α·I]`` with zero-extended
        targets — QR of the augmented matrix is the textbook
        squaring-free ridge, so an explicit ``solver="tsqr"`` never drops
        the requested penalty."""
        from repro.algorithms.linalg import tsqr
        from repro.core.dsarray import concat_rows, from_array as _fa
        if x.is_sparse:
            # QR factors are dense whatever the input; centering below
            # would densify anyway — callers on sparse data keep the
            # (ridge-regularized) normal equations instead
            raise ValueError("tsqr solver supports dense inputs only")
        n, m = x.shape
        if n < m:
            raise ValueError("tsqr solver needs a tall (n >= m) input")
        if x.block_shape[0] < m:
            # tsqr's leaf QR needs m <= block rows: re-block (block-native)
            x = x.rechunk((min(n, max(x.block_shape[0], m)),
                           x.block_shape[1]))
        yv = np.asarray(y, np.float64)
        if self.fit_intercept:
            from repro.algorithms.linalg import _broadcast_rows
            mean_row = x.mean(axis=0)
            xc = x - _broadcast_rows(mean_row, x.shape[0], x.block_shape[0])
            ym = yv.mean()
            yc = yv - ym
        else:
            xc, yc, ym = x, yv, 0.0
        if self.alpha > 0.0:
            ridge_rows = _fa(np.sqrt(self.alpha) * np.eye(m, dtype=np.float32),
                             xc.block_shape)
            xc = concat_rows([xc, ridge_rows])
            yc = np.concatenate([yc, np.zeros(m)])
        q, r = tsqr(xc)
        qty = np.asarray(q, np.float64).T @ yc
        try:
            coef = require_finite_host(
                np.linalg.solve(np.asarray(r, np.float64), qty),
                "tsqr R-solve solution")
        except (np.linalg.LinAlgError, NumericalDivergence):
            # singular R (exactly collinear/zero columns): min-norm solve
            coef = np.linalg.lstsq(np.asarray(r, np.float64), qty,
                                   rcond=None)[0]
        if self.fit_intercept:
            mean = np.asarray(mean_row.collect(), np.float64).ravel()
            return coef, float(ym - mean @ coef)
        return coef, 0.0

    def fit(self, x, y) -> "LinearRegression":
        with self._driver_scope():
            return self._fit(x, y)

    def _fit(self, x, y) -> "LinearRegression":
        x, y = self._validate_fit(x, y)
        n, m = x.shape
        self.n_features_in_ = m
        if self.solver not in ("auto", "normal", "tsqr"):
            raise ValueError(f"unknown solver {self.solver!r}")
        solver = self.solver
        gram = xty = colsum = None
        if solver != "tsqr":
            gram, xty, colsum = self._normal_stats(x, y)
            if solver == "auto" and self.alpha == 0.0 and not x.is_sparse \
                    and n >= m:
                ev = np.linalg.eigvalsh(gram)
                lo, hi = max(float(ev[0]), 0.0), float(ev[-1])
                # cond(X) = sqrt(cond(XᵀX)); degenerate spectrum → fallback
                if lo <= 0 or np.sqrt(hi / lo) > _COND_FALLBACK:
                    solver = "tsqr"
                else:
                    solver = "normal"
            elif solver == "auto":
                solver = "normal"
        if solver == "tsqr":
            self.coef_, self.intercept_ = self._solve_tsqr(x, y)
        else:
            self.coef_, self.intercept_ = self._solve_normal(
                gram, xty, colsum, n, float(np.asarray(y, np.float64).sum()))
        self.solver_used_ = solver
        return self

    def _weights_ds(self, block_cols: int) -> DsArray:
        """``coef_`` as a device-pinned ``(m, 1)`` ds-array, cached per
        column blocking AND per fitted-coefficient identity: the serving
        hot path re-records predict per request batch, and reusing ONE leaf
        array keeps the plan's alias structure stable (``_OPT_CACHE`` hits)
        and skips a host->device transfer per request.  A refit (new
        ``coef_`` object) naturally invalidates the cached entry."""
        cache = self.__dict__.setdefault("_predict_cache", {})
        key = (int(block_cols), id(self.coef_))
        w = cache.get(key)
        if w is None:
            cache.clear()                    # one fit, one blocking at a time
            w = from_array(jnp.asarray(self.coef_, jnp.float32).reshape(-1, 1),
                           (block_cols, 1))
            jax.block_until_ready(w.blocks)
            cache[key] = w
        return w

    def _predict_expr(self, xl):
        """``x @ coef_ + intercept_`` recorded on the lazy input: the
        matmul is the sparse-native ``sp @ dense`` path for bcoo inputs,
        and the whole expression is one cacheable plan (the serve layer's
        AOT target)."""
        out = xl @ self._weights_ds(xl.block_shape[1])
        if self.intercept_ != 0.0:
            out = out + float(self.intercept_)
        return out

    def predict(self, x) -> DsArray:
        """``x @ coef_ + intercept_`` as a new ``(n, 1)`` ds-array,
        computed through the SAME recorded plan the serving layer caches
        (``_predict_expr``), so direct and served predictions are
        bit-identical and repeat predicts hit the structural plan cache."""
        self._check_fitted("coef_")
        with self._driver_scope():
            x = self._validate_x(x)
            return plan.compute(self._predict_expr(x.lazy()))


@dataclasses.dataclass
class Ridge(LinearRegression):
    """L2-regularized linear regression: the Gram gets ``alpha`` added to
    its diagonal (intercept unpenalized), which also keeps the normal
    equations well-posed on rank-deficient inputs — so Ridge never needs
    the TSQR fallback."""

    alpha: float = 1.0
