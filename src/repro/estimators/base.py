"""Estimator contract for the dislib-style fit/predict layer.

The paper's point is that the ds-array exists to power dislib's estimator
collection (CSVM, random forest, linear models) behind a NumPy/sklearn-like
API; this module is the contract every estimator in ``repro.estimators``
(and the refactored ``repro.algorithms`` classes) implements:

* ``fit(x[, y]) -> self`` with ``x`` a ds-array (dense **or** bcoo block
  format, any block grid) and ``y`` a ds-array / array of targets;
* ``predict(x) -> DsArray`` returning a NEW ``(n, 1)`` distributed array
  (the paper's API fix: predict never mutates its input);
* ``score(x, y) -> float`` (accuracy for classifiers, R² for regressors,
  model-specific otherwise);
* ``get_params() / set_params(**p)`` over the constructor parameters —
  estimators are dataclasses, and the convention is sklearn's: fields whose
  name ends in ``_`` are FITTED state, everything else is a parameter.

Fit loops are expressed over the lazy expression layer (``repro.lazy()`` /
``DsArray.lazy()``): each iteration re-records a structurally identical
plan, so iteration 2..N skip both the optimizer (``plan._OPT_CACHE``) and
XLA compilation (``plan._CACHE``) — the TPU analogue of PyCOMPSs reusing
one task graph per iteration.  ``tests/test_estimators.py`` regression-
tests ``opt_runs == 1`` across a 5-iteration CSVM fit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dsarray import DsArray, from_array


class NotFittedError(RuntimeError):
    pass


@dataclasses.dataclass
class BaseEstimator:
    """get_params/set_params + input validation over dataclass fields.

    Subclasses are ``@dataclasses.dataclass``; parameter fields precede
    fitted fields (named with a trailing underscore and defaulted) so the
    generated ``__init__`` keeps the sklearn constructor shape.
    """

    # -- parameter protocol --------------------------------------------------
    def get_params(self) -> dict:
        """Constructor parameters (dataclass fields without a trailing
        underscore), as a plain dict — round-trips through
        ``type(self)(**params)`` and ``set_params``."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if not f.name.endswith("_")}

    def set_params(self, **params) -> "BaseEstimator":
        """Update parameters in place; unknown names raise (the sklearn
        contract — silent typos in grid searches are the classic bug)."""
        valid = {f.name for f in dataclasses.fields(self)
                 if not f.name.endswith("_")}
        for name, value in params.items():
            if name not in valid:
                raise ValueError(
                    f"unknown parameter {name!r} for "
                    f"{type(self).__name__}; valid: {sorted(valid)}")
            setattr(self, name, value)
        return self

    @staticmethod
    def _driver_scope():
        """Mask ambient ``repro.lazy()`` recording around estimator driver
        code.  Estimators record their hot loops through EXPLICIT
        ``.lazy()`` lifts (which record regardless of the ambient flag), so
        the validation/chunking/host-solver glue must stay eager even when
        a caller wraps ``fit`` in the context manager — otherwise a stray
        recorded slice would reach a host solver as a LazyDsArray."""
        from repro.core import expr
        return expr.suspend_lazy()

    def _check_fitted(self, attr: str) -> None:
        if getattr(self, attr, None) is None:
            raise NotFittedError(
                f"{type(self).__name__}: call fit before predict/score")

    # -- input validation ----------------------------------------------------
    @staticmethod
    def _validate_x(x, default_block_rows: int = 128) -> DsArray:
        """``x`` as a 2-D ds-array: DsArray (dense or bcoo) pass through
        untouched — validation must never densify a sparse input — and raw
        2-D arrays are blocked with a default grid."""
        if isinstance(x, DsArray):
            return x
        arr = np.asarray(x)
        if arr.ndim != 2:
            raise ValueError(f"estimator inputs are 2-D, got shape {arr.shape}")
        bn = min(default_block_rows, max(1, arr.shape[0]))
        return from_array(jnp.asarray(arr), (bn, max(1, arr.shape[1])))

    @staticmethod
    def _validate_y(y, n_rows: int) -> np.ndarray:
        """Targets as a 1-D host vector of length ``n_rows``.  Accepts a
        ``(n, 1)``/``(1, n)`` ds-array or any array-like; targets are O(n)
        and consumed by host-side solver drivers, so collecting them is not
        a materialization of the data matrix."""
        if isinstance(y, DsArray):
            if 1 not in y.shape:
                raise ValueError(f"y must be a vector, got shape {y.shape}")
            y = np.asarray(y.collect()).ravel()
        else:
            y = np.asarray(y).ravel()
        if y.shape[0] != n_rows:
            raise ValueError(
                f"x has {n_rows} rows but y has {y.shape[0]} entries")
        return y

    def _validate_fit(self, x, y) -> Tuple[DsArray, np.ndarray]:
        x = self._validate_x(x)
        return x, self._validate_y(y, x.shape[0])

    @staticmethod
    def _labels_ds(values: np.ndarray, like: DsArray) -> DsArray:
        """A 1-D result vector as the conventional ``(n, 1)`` ds-array,
        blocked to match ``like``'s row blocking."""
        return from_array(jnp.asarray(values).reshape(-1, 1),
                          (like.block_shape[0], 1))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        params = ", ".join(f"{k}={v!r}" for k, v in self.get_params().items())
        return f"{type(self).__name__}({params})"


@dataclasses.dataclass
class BaseClassifier(BaseEstimator):
    """Classifier mixin: label encoding + accuracy score."""

    def _encode_labels(self, y: np.ndarray,
                       n_classes: Optional[int] = None) -> np.ndarray:
        """Store ``classes_`` and return integer-encoded labels."""
        classes, encoded = np.unique(y, return_inverse=True)
        if not np.issubdtype(classes.dtype, np.number):
            # predictions travel back as (n, 1) ds-arrays, which are
            # numeric tensors — string labels would fit fine and then
            # crash predict, so reject them up front
            raise ValueError(
                f"{type(self).__name__} needs numeric labels, got dtype "
                f"{classes.dtype}; encode them first")
        if n_classes is not None and len(classes) != n_classes:
            raise ValueError(
                f"{type(self).__name__} needs exactly {n_classes} classes, "
                f"got {len(classes)}: {classes}")
        self.classes_ = classes
        return encoded

    def score(self, x, y) -> float:
        """Mean accuracy of ``predict(x)`` against ``y``."""
        x = self._validate_x(x)
        y = self._validate_y(y, x.shape[0])
        pred = np.asarray(self.predict(x).collect()).ravel()
        return float((pred == y).mean())


@dataclasses.dataclass
class BaseRegressor(BaseEstimator):
    """Regressor mixin: R² score."""

    def score(self, x, y) -> float:
        """Coefficient of determination R² of ``predict(x)`` vs ``y``."""
        x = self._validate_x(x)
        y = self._validate_y(y, x.shape[0]).astype(np.float64)
        pred = np.asarray(self.predict(x).collect()).ravel().astype(np.float64)
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum())
        return 1.0 - ss_res / ss_tot if ss_tot > 0 else \
            (1.0 if ss_res == 0 else 0.0)
