"""Estimator contract for the dislib-style fit/predict layer.

The paper's point is that the ds-array exists to power dislib's estimator
collection (CSVM, random forest, linear models) behind a NumPy/sklearn-like
API; this module is the contract every estimator in ``repro.estimators``
(and the refactored ``repro.algorithms`` classes) implements:

* ``fit(x[, y]) -> self`` with ``x`` a ds-array (dense **or** bcoo block
  format, any block grid) and ``y`` a ds-array / array of targets;
* ``predict(x) -> DsArray`` returning a NEW ``(n, 1)`` distributed array
  (the paper's API fix: predict never mutates its input);
* ``score(x, y) -> float`` (accuracy for classifiers, R² for regressors,
  model-specific otherwise);
* ``get_params() / set_params(**p)`` over the constructor parameters —
  estimators are dataclasses, and the convention is sklearn's: fields whose
  name ends in ``_`` are FITTED state, everything else is a parameter.

Fit loops are expressed over the lazy expression layer (``repro.lazy()`` /
``DsArray.lazy()``): each iteration re-records a structurally identical
plan, so iteration 2..N skip both the optimizer (``plan._OPT_CACHE``) and
XLA compilation (``plan._CACHE``) — the TPU analogue of PyCOMPSs reusing
one task graph per iteration.  ``tests/test_estimators.py`` regression-
tests ``opt_runs == 1`` across a 5-iteration CSVM fit.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as _plan
from repro.core.dsarray import DsArray, from_array
from repro import checkpoint as _ckpt


class NotFittedError(RuntimeError):
    pass


def _fire(site: str, **info) -> None:
    """Fault-injection hook for estimator fit loops: consult
    ``repro.resilience.inject`` only when a chaos test already imported it
    (one sys.modules lookup on the clean path)."""
    ri = sys.modules.get("repro.resilience.inject")
    if ri is not None:
        ri.maybe_fire(site, **info)


def _iter_span(est, iteration: int):
    """One ``fit.iteration`` trace span per outer fit-loop pass (the no-op
    singleton when tracing is off) — every host-driven fit loop wraps its
    body in this, next to its ``_fire("fit_iteration", ...)`` hook."""
    from repro.obs import tracing as _tracing
    return _tracing.span("fit.iteration", estimator=type(est).__name__,
                         iteration=iteration)


# ---------------------------------------------------------------------------
# Fitted-state (de)serialization over the trailing-underscore convention
# ---------------------------------------------------------------------------
#
# A fitted estimator's state is, by the dataclass contract above, exactly
# its ``name_`` attributes.  Packing splits that dict into an array pytree
# (stored as checkpoint leaves) and JSON-able metadata (stored in the
# manifest ``extra``): scalars inline, DsArray fields as collected arrays +
# blocking so load rebuilds the distributed layout.  The same pack/unpack
# pair backs ``save_model``/``load_model`` AND the per-iteration fit
# checkpoints (``_FitCheckpoint``) — one wire format, one set of bugs.

MODEL_FORMAT = "repro-model-v1"


def _pack_state(state: Dict[str, Any]) -> Tuple[Dict[str, np.ndarray], dict]:
    arrays: Dict[str, np.ndarray] = {}
    meta: dict = {"scalars": {}, "arrays": [], "ds": {}}
    for k, v in state.items():
        if isinstance(v, np.generic):
            v = v.item()
        if isinstance(v, DsArray):
            meta["ds"][k] = {"block_shape": list(v.block_shape),
                             "sparse": bool(v.is_sparse)}
            arrays[k] = np.asarray(v.collect())
        elif isinstance(v, (np.ndarray, jax.Array)):
            meta["arrays"].append(k)
            arrays[k] = np.asarray(v)
        elif isinstance(v, (bool, int, float, str)) or v is None:
            meta["scalars"][k] = v
        else:
            raise TypeError(
                f"cannot serialize fitted field {k!r} of type "
                f"{type(v).__name__}; supported: scalars, arrays, DsArray")
    return arrays, meta


def _unpack_state(arrays: Dict[str, np.ndarray], meta: dict) -> Dict[str, Any]:
    out: Dict[str, Any] = dict(meta["scalars"])
    for k in meta["arrays"]:
        out[k] = jnp.asarray(arrays[k])
    for k, info in meta["ds"].items():
        a = from_array(jnp.asarray(arrays[k]), tuple(info["block_shape"]))
        if info["sparse"]:
            a = a.tosparse()
        out[k] = a
    return out


def _load_arrays(root: str, step: int) -> Dict[str, np.ndarray]:
    """Restore a flat name->array checkpoint WITHOUT caller-side protos:
    the ``like`` tree is rebuilt from the manifest's recorded shapes/dtypes
    (so dtype fidelity is exact — no ``allow_cast`` needed)."""
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    like = {e["path"]: np.zeros(tuple(e["shape"]), dtype=np.dtype(e["dtype"]))
            for e in man["leaves"]}
    return _ckpt.restore(root, step, like)


def resolve_estimator(name: str) -> type:
    """Estimator class by name — ``repro.estimators`` exports first, then
    ``repro.algorithms`` (imported lazily HERE, at call time: the import
    graph must stay acyclic — see the package docstring)."""
    import repro.estimators as _pkg
    klass = getattr(_pkg, name, None)
    if klass is None:
        import importlib
        alg = importlib.import_module("repro.algorithms")
        klass = getattr(alg, name, None)
    if not (isinstance(klass, type) and issubclass(klass, BaseEstimator)):
        raise KeyError(f"unknown estimator {name!r} in model checkpoint")
    return klass


class _FitCheckpoint:
    """Per-outer-iteration fit state in the ``checkpoint/`` layout.

    ``save(it, state)`` commits atomically (step == iteration), so a crash
    mid-write leaves the previous committed iteration as newest; ``load()``
    returns ``(iteration, state)`` for the newest committed state (or None
    when the directory is empty — fresh start).  The estimator name is
    recorded and verified so resuming a CSVM fit from an ALS directory
    fails loudly instead of unpacking garbage.
    """

    def __init__(self, directory: str, estimator: str):
        self.directory = directory
        self.estimator = estimator

    def save(self, iteration: int, state: Dict[str, Any]) -> None:
        arrays, meta = _pack_state(state)
        _ckpt.save(self.directory, iteration, arrays,
                   extra={"format": MODEL_FORMAT, "estimator": self.estimator,
                          "iteration": iteration, "state": meta})

    def load(self, iteration: Optional[int] = None):
        it = iteration if iteration is not None \
            else _ckpt.latest_step(self.directory)
        if it is None:
            return None
        extra = _ckpt.manifest_extra(self.directory, it)
        if extra.get("estimator") != self.estimator:
            raise ValueError(
                f"resume directory {self.directory!r} holds "
                f"{extra.get('estimator')!r} state, not {self.estimator!r}")
        return it, _unpack_state(_load_arrays(self.directory, it),
                                 extra["state"])


@dataclasses.dataclass
class BaseEstimator:
    """get_params/set_params + input validation over dataclass fields.

    Subclasses are ``@dataclasses.dataclass``; parameter fields precede
    fitted fields (named with a trailing underscore and defaulted) so the
    generated ``__init__`` keeps the sklearn constructor shape.
    """

    # -- parameter protocol --------------------------------------------------
    def get_params(self) -> dict:
        """Constructor parameters (dataclass fields without a trailing
        underscore), as a plain dict — round-trips through
        ``type(self)(**params)`` and ``set_params``."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if not f.name.endswith("_")}

    def set_params(self, **params) -> "BaseEstimator":
        """Update parameters in place; unknown names raise (the sklearn
        contract — silent typos in grid searches are the classic bug)."""
        valid = {f.name for f in dataclasses.fields(self)
                 if not f.name.endswith("_")}
        for name, value in params.items():
            if name not in valid:
                raise ValueError(
                    f"unknown parameter {name!r} for "
                    f"{type(self).__name__}; valid: {sorted(valid)}")
            setattr(self, name, value)
        return self

    # -- model (de)serialization ---------------------------------------------
    def _fitted_state(self) -> Dict[str, Any]:
        """The trailing-underscore attributes (declared fields AND ones set
        dynamically, e.g. ``classes_`` from ``_encode_labels``)."""
        return {k: v for k, v in vars(self).items()
                if k.endswith("_") and not k.startswith("_")}

    def _is_fitted(self, fitted: Optional[Dict[str, Any]] = None) -> bool:
        """Fitted means some trailing-underscore attribute moved off its
        declared dataclass default — unfitted estimators still carry
        non-None scalar defaults like ``intercept_ = 0.0``."""
        if fitted is None:
            fitted = self._fitted_state()
        defaults = {}
        if dataclasses.is_dataclass(self):
            for f in dataclasses.fields(self):
                if f.default is not dataclasses.MISSING:
                    defaults[f.name] = f.default
        for k, v in fitted.items():
            if v is None:
                continue
            if isinstance(v, (bool, int, float, str)) and k in defaults \
                    and v == defaults[k]:
                continue
            return True
        return False

    def save_model(self, directory: str, version: int = 0) -> str:
        """Persist params + fitted state through ``repro.checkpoint``
        (atomic commit; ``load_model`` restores with exact dtypes).  The
        registry entry point for the serving layer: the manifest records
        the estimator class so ``estimators.load_model(dir)`` reconstructs
        without knowing the type.  ``version`` maps onto the checkpoint
        step, so one directory holds a version history and
        ``serve.ModelRegistry`` serves any pinned version of it."""
        fitted = self._fitted_state()
        if not self._is_fitted(fitted):
            raise NotFittedError(
                f"{type(self).__name__}: nothing fitted to save")
        arrays, meta = _pack_state(fitted)
        return _ckpt.save(
            directory, version, arrays,
            extra={"format": MODEL_FORMAT,
                   "estimator": type(self).__name__,
                   "version": version,
                   "params": self.get_params(), "state": meta})

    @classmethod
    def load_model(cls, directory: str,
                   version: Optional[int] = None) -> "BaseEstimator":
        """Reconstruct a fitted estimator saved by ``save_model``.  Call on
        the concrete class (checked against the manifest) or on
        ``BaseEstimator``/via ``estimators.load_model`` to dispatch through
        the registry.  ``version=None`` loads the newest committed version
        in the directory."""
        step = version if version is not None \
            else _ckpt.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no model checkpoint in {directory!r}")
        extra = _ckpt.manifest_extra(directory, step)
        name = extra.get("estimator")
        if cls is BaseEstimator:
            klass = resolve_estimator(name)
        else:
            if name != cls.__name__:
                raise ValueError(
                    f"{directory!r} holds a {name!r} model, not "
                    f"{cls.__name__}")
            klass = cls
        est = klass(**extra["params"])
        for k, v in _unpack_state(_load_arrays(directory, step),
                                  extra["state"]).items():
            setattr(est, k, v)
        return est

    @staticmethod
    def _driver_scope():
        """Mask ambient ``repro.lazy()`` recording around estimator driver
        code.  Estimators record their hot loops through EXPLICIT
        ``.lazy()`` lifts (which record regardless of the ambient flag), so
        the validation/chunking/host-solver glue must stay eager even when
        a caller wraps ``fit`` in the context manager — otherwise a stray
        recorded slice would reach a host solver as a LazyDsArray."""
        from repro.core import expr
        return expr.suspend_lazy()

    def _check_fitted(self, attr: str) -> None:
        if getattr(self, attr, None) is None:
            raise NotFittedError(
                f"{type(self).__name__}: call fit before predict/score")

    # -- predict-plan capture (the serving layer's entry point) --------------
    def _predict_expr(self, xl):
        """Record this estimator's predict on the lazy-lifted input ``xl``
        (a ``LazyDsArray``) and return the recorded lazy result.

        Estimators whose predict lowers through the lazy expression layer
        implement this (linear models do); ``predict`` and
        :meth:`predict_plan` both route through it, so a served plan
        computes EXACTLY what direct ``predict`` computes — same recorded
        structure, same compiled program, bit-identical outputs.  The
        default raises ``NotImplementedError``: the serve layer then falls
        back to eager ``predict`` (still geometry-bucketed, just without
        an AOT-warmed plan).
        """
        raise NotImplementedError(
            f"{type(self).__name__} has no recordable predict plan")

    def has_predict_plan(self) -> bool:
        """True when :meth:`_predict_expr` is overridden — i.e. predict
        can be captured as a cacheable, AOT-compilable lazy plan."""
        return type(self)._predict_expr is not BaseEstimator._predict_expr

    def predict_plan(self, x) -> "_plan.Plan":
        """``predict(x)`` captured as ONE optimized :class:`~repro.core.plan.Plan`
        (not executed).  The serve layer records a plan per request batch —
        structurally identical batches skip the optimizer
        (``plan._OPT_CACHE``) and hit the compiled cache (``plan._CACHE``),
        and :meth:`Plan.compile_aot` warms the compiled entry at model-load
        time so no request pays first-call XLA compilation."""
        with self._driver_scope():
            x = self._validate_x(x)
            lz = self._predict_expr(x.lazy())
        return _plan.plan_for(lz)

    # -- input validation ----------------------------------------------------
    @staticmethod
    def _validate_x(x, default_block_rows: int = 128) -> DsArray:
        """``x`` as a 2-D ds-array: DsArray (dense or bcoo) pass through
        untouched — validation must never densify a sparse input — and raw
        2-D arrays are blocked with a default grid."""
        if isinstance(x, DsArray):
            return x
        arr = np.asarray(x)
        if arr.ndim != 2:
            raise ValueError(f"estimator inputs are 2-D, got shape {arr.shape}")
        bn = min(default_block_rows, max(1, arr.shape[0]))
        return from_array(jnp.asarray(arr), (bn, max(1, arr.shape[1])))

    @staticmethod
    def _validate_y(y, n_rows: int) -> np.ndarray:
        """Targets as a 1-D host vector of length ``n_rows``.  Accepts a
        ``(n, 1)``/``(1, n)`` ds-array or any array-like; targets are O(n)
        and consumed by host-side solver drivers, so collecting them is not
        a materialization of the data matrix."""
        if isinstance(y, DsArray):
            if 1 not in y.shape:
                raise ValueError(f"y must be a vector, got shape {y.shape}")
            y = np.asarray(y.collect()).ravel()
        else:
            y = np.asarray(y).ravel()
        if y.shape[0] != n_rows:
            raise ValueError(
                f"x has {n_rows} rows but y has {y.shape[0]} entries")
        return y

    def _validate_fit(self, x, y) -> Tuple[DsArray, np.ndarray]:
        x = self._validate_x(x)
        return x, self._validate_y(y, x.shape[0])

    @staticmethod
    def _labels_ds(values: np.ndarray, like: DsArray) -> DsArray:
        """A 1-D result vector as the conventional ``(n, 1)`` ds-array,
        blocked to match ``like``'s row blocking."""
        return from_array(jnp.asarray(values).reshape(-1, 1),
                          (like.block_shape[0], 1))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        params = ", ".join(f"{k}={v!r}" for k, v in self.get_params().items())
        return f"{type(self).__name__}({params})"


@dataclasses.dataclass
class BaseClassifier(BaseEstimator):
    """Classifier mixin: label encoding + accuracy score."""

    def _encode_labels(self, y: np.ndarray,
                       n_classes: Optional[int] = None) -> np.ndarray:
        """Store ``classes_`` and return integer-encoded labels."""
        classes, encoded = np.unique(y, return_inverse=True)
        if not np.issubdtype(classes.dtype, np.number):
            # predictions travel back as (n, 1) ds-arrays, which are
            # numeric tensors — string labels would fit fine and then
            # crash predict, so reject them up front
            raise ValueError(
                f"{type(self).__name__} needs numeric labels, got dtype "
                f"{classes.dtype}; encode them first")
        if n_classes is not None and len(classes) != n_classes:
            raise ValueError(
                f"{type(self).__name__} needs exactly {n_classes} classes, "
                f"got {len(classes)}: {classes}")
        self.classes_ = classes
        return encoded

    def score(self, x, y) -> float:
        """Mean accuracy of ``predict(x)`` against ``y``."""
        x = self._validate_x(x)
        y = self._validate_y(y, x.shape[0])
        pred = np.asarray(self.predict(x).collect()).ravel()
        return float((pred == y).mean())


@dataclasses.dataclass
class BaseRegressor(BaseEstimator):
    """Regressor mixin: R² score."""

    def score(self, x, y) -> float:
        """Coefficient of determination R² of ``predict(x)`` vs ``y``."""
        x = self._validate_x(x)
        y = self._validate_y(y, x.shape[0]).astype(np.float64)
        pred = np.asarray(self.predict(x).collect()).ravel().astype(np.float64)
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum())
        return 1.0 - ss_res / ss_tot if ss_tot > 0 else \
            (1.0 if ss_res == 0 else 0.0)
