"""repro — ds-array reproduction on JAX/TPU.

Top-level conveniences: ``repro.lazy()`` arms lazy recording for ds-array
ops (the paper's task-graph view; see ``repro.core.expr``), and the ds-array
type/constructors re-export from ``repro.core``.
"""

from repro.core.expr import LazyDsArray, lazy
from repro.core.dsarray import DsArray, from_array
from repro import estimators

__all__ = ["lazy", "LazyDsArray", "DsArray", "from_array", "estimators"]
