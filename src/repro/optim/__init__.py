"""Optimizers (from scratch): AdamW, Adafactor, schedules, grad clipping."""

from repro.optim.adamw import (AdamW, Adafactor, clip_by_global_norm,
                               cosine_schedule, global_norm, make_optimizer)

__all__ = ["AdamW", "Adafactor", "clip_by_global_norm", "cosine_schedule",
           "global_norm", "make_optimizer"]
