"""AdamW and Adafactor, from scratch (no optax), pytree-functional.

Moment dtype is configurable (bf16 moments halve optimizer HBM — required to
fit grok-1-314b on a 256-chip pod; see EXPERIMENTS.md §Dry-run).  Adafactor
factors the second moment for another ~2x on the biggest models.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray]   # step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # "bfloat16" halves optimizer memory

    def init(self, params: Params) -> Params:
        mdt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads: Params, state: Params, params: Params
               ) -> Tuple[Params, Params, dict]:
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        count = state["count"] + 1
        b1, b2 = self.b1, self.b2
        mdt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = mf / (1 - b1 ** count)
            vhat = vf / (1 - b2 ** count)
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + self.weight_decay * p.astype(jnp.float32)
            return step, mf.astype(mdt), vf.astype(mdt)

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        steps = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        ms = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
        vs = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
        lr = self.learning_rate(count)
        new_params = jax.tree_util.tree_map(
            lambda p, s: (p.astype(jnp.float32) - lr * s).astype(p.dtype),
            params, steps)
        new_state = {"m": ms, "v": vs, "count": count}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second moments (Shazeer & Stern): O(n+m) optimizer memory per
    (n, m) matrix instead of O(n·m) — the huge-model option."""
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray]
    decay: float = 0.8
    eps: float = 1e-30
    clip_norm: float = 1.0
    weight_decay: float = 0.0

    def init(self, params: Params) -> Params:
        def factored(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree_util.tree_map(
                    factored, params,
                    is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        count = state["count"] + 1
        beta = 1.0 - (count.astype(jnp.float32) + 1.0) ** (-self.decay)

        def upd(g, v, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + self.eps
            if p.ndim >= 2:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None], self.eps))
                step = gf / jnp.sqrt(denom + self.eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                step = gf / jnp.sqrt(nv["v"] + self.eps)
            if p.ndim >= 2 and self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return step, nv

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        pairs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        steps = treedef.unflatten([s for s, _ in pairs])
        new_v = treedef.unflatten([v for _, v in pairs])
        lr = self.learning_rate(count)
        new_params = jax.tree_util.tree_map(
            lambda p, s: (p.astype(jnp.float32) - lr * s).astype(p.dtype),
            params, steps)
        return new_params, {"v": new_v, "count": count}, \
            {"grad_norm": gnorm, "lr": lr}


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def make_optimizer(kind: str, peak_lr: float = 3e-4, warmup: int = 100,
                   total: int = 10000, moment_dtype: str = "float32",
                   weight_decay: float = 0.1):
    sched = cosine_schedule(peak_lr, warmup, total)
    if kind == "adamw":
        return AdamW(learning_rate=sched, moment_dtype=moment_dtype,
                     weight_decay=weight_decay)
    if kind == "adafactor":
        return Adafactor(learning_rate=sched, weight_decay=weight_decay)
    raise KeyError(kind)
