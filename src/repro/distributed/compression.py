"""int8 stochastic-rounding gradient compression for the slow cross-pod axis.

``compressed_psum`` reproduces ring-all-reduce semantics at ~1/4 the bytes of
a bf16 reduce: int8 all_to_all (reduce-scatter phase, dequant-accumulate in
fp32 locally) + int8 all_gather (broadcast phase).  Stochastic rounding keeps
the quantizer unbiased, so SGD sees zero-mean noise rather than bias.

Used inside ``shard_map`` over the ``pod`` axis (validated in
``tests/test_distributed.py::test_compressed_psum_unbiased``); intra-pod
reductions stay uncompressed.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unbiased int8 quantization with per-tensor scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    noise = jax.random.uniform(key, y.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jnp.ndarray, axis: str, key,
                    axis_size: int) -> jnp.ndarray:
    """Sum ``x`` over mesh axis ``axis`` with int8 transport.

    Call inside shard_map. x: identical-shape local tensor per device.
    """
    n = x.size
    pad = (-n) % axis_size
    flat = jnp.pad(x.astype(jnp.float32).reshape(-1), (0, pad))
    chunks = flat.reshape(axis_size, -1)

    q, scale = _quantize(chunks, key)
    # reduce-scatter phase: device i collects chunk i from every peer
    recv = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0,
                              tiled=True)                    # (P, chunk)
    scales = jax.lax.all_gather(scale, axis)                 # (P,)
    partial = jnp.sum(recv.astype(jnp.float32)
                      * scales[:, None], axis=0)             # (chunk,)

    # broadcast phase
    q2, s2 = _quantize(partial, jax.random.fold_in(key, 1))
    full = jax.lax.all_gather(q2, axis)                      # (P, chunk)
    s2a = jax.lax.all_gather(s2, axis)                       # (P,)
    out = (full.astype(jnp.float32) * s2a[:, None]).reshape(-1)
    return out[:n].reshape(x.shape).astype(x.dtype)
