from repro.distributed.sharding import (opt_state_shardings, param_shardings,
                                        param_specs, spec_for_path)
from repro.distributed.compression import compressed_psum
from repro.distributed.fault_tolerance import (Heartbeat, run_with_restarts)
