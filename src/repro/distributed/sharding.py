"""Name-based parameter sharding rules (DP/FSDP/TP/EP over logical axes).

Strategy (per DESIGN.md §3):

* batch over the data-parallel axes ``("pod", "data")`` (pod optional),
* FSDP (ZeRO-3): parameters AND optimizer state sharded over ``"data"``,
  all-gathered on use by GSPMD,
* TP (Megatron): attention heads / MLP hidden / vocab over ``"model"``,
* the embedding & head tables are ds-array-style 2-D blocked:
  (vocab × d_model) over ("model" × "data") — the paper's 2-D blocking
  applied to the largest tables (gemma2/nemotron: 256k vocab),
* experts: TP over d_ff within each expert + FSDP over d_model (expert count
  8 does not divide the 16-wide model axis, so pure EP is not used; see
  DESIGN.md §Arch-applicability),
* cross-pod: parameters are REPLICATED over "pod" — the only cross-pod
  traffic is the gradient reduction (optionally int8-compressed).

Rules match on the path suffix of each parameter leaf; leading stacked-layer
dims (from scan-over-layers) are padded with None automatically.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on leaf path, spec on the leaf's LAST len(spec) dims)
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / heads: 2-D ds-array blocking (vocab x d_model)
    (r"embed$",                    ("model", "data")),
    (r"lm_head$",                  ("data", "model")),
    (r"frontend_proj$",            (None, "model")),
    (r"mm_proj/w1$",               (None, "model")),
    (r"mm_proj/w2$",               ("data", "model")),
    # attention: FSDP on d_model, TP on heads
    (r"attn/w[qkv]$",              ("data", "model")),
    (r"(self|cross)_attn/w[qkv]$", ("data", "model")),
    (r"attn/wo$",                  ("model", "data")),
    (r"(self|cross)_attn/wo$",     ("model", "data")),
    (r"attn/b[qkv]$",              ("model",)),
    # dense MLP
    (r"mlp/w_(gate|up)$",          ("data", "model")),
    (r"mlp/w_down$",               ("model", "data")),
    # MoE: experts replicated on E, FSDP on d, TP on f
    (r"moe/router$",               ("data", None)),
    (r"moe/w_(gate|up)$",          (None, "data", "model")),
    (r"moe/w_down$",               (None, "model", "data")),
    # mamba2
    (r"in_proj$",                  ("data", "model")),
    (r"out_proj$",                 ("model", "data")),
    (r"conv_w$",                   (None, "model")),
    (r"conv_b$",                   ("model",)),
    (r"gate_norm$",                ("model",)),
    # everything else (norms, scalars, A_log, ...) replicated
)


def spec_for_path(path: str, ndim: int) -> P:
    for pat, suffix in _RULES:
        if re.search(pat, path):
            if len(suffix) > ndim:
                return P()
            pad = (None,) * (ndim - len(suffix))
            return P(*(pad + tuple(suffix)))
    return P()


def tree_paths(tree) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return paths, [leaf for _, leaf in flat], treedef


def _axis_extent(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Replicate any dim the mesh extent does not divide evenly."""
    out = []
    for i, names in enumerate(spec):
        if names is not None and (i >= len(shape)
                                  or shape[i] % _axis_extent(mesh, names) != 0):
            out.append(None)
        else:
            out.append(names)
    return P(*out)


def param_specs(params, mesh: Optional[Mesh] = None) -> Any:
    """Pytree of PartitionSpec matching ``params`` (sanitized if mesh given)."""
    paths, leaves, treedef = tree_paths(params)
    specs = [spec_for_path(p, getattr(l, "ndim", 0)) for p, l in zip(paths, leaves)]
    if mesh is not None:
        specs = [sanitize_spec(s, getattr(l, "shape", ()), mesh)
                 for s, l in zip(specs, leaves)]
    return treedef.unflatten(specs)


def param_shardings(params, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  param_specs(params, mesh),
                                  is_leaf=lambda x: isinstance(x, P))


# -- activation / batch / cache shardings -------------------------------------

_CACHE_RULES = (
    (r"(attn_k|attn_v|k|v)$", (None, "dp", None, "model", None)),  # (L,B,H,T,hd)
    (r"enc_out$",             ("dp", None, "model")),              # (B,T,D)
    (r"conv$",                (None, "dp", None, "model")),        # (L,B,K,C)
    (r"h$",                   (None, "dp", "model", None, None)),  # (L,B,H,S,P)
)


def _expand_dp(names, dp: Tuple[str, ...]):
    if names == "dp":
        return dp
    return names


def cache_specs(cache, mesh: Mesh, dp: Tuple[str, ...]) -> Any:
    paths, leaves, treedef = tree_paths(cache)
    out = []
    for p, l in zip(paths, leaves):
        ndim = getattr(l, "ndim", 0)
        spec = P()
        for pat, suffix in _CACHE_RULES:
            if re.search(pat, p) and len(suffix) == ndim:
                spec = P(*[_expand_dp(n, dp) for n in suffix])
                break
        out.append(sanitize_spec(spec, getattr(l, "shape", ()), mesh))
    return treedef.unflatten(out)


def batch_specs(batch, mesh: Mesh, dp: Tuple[str, ...]) -> Any:
    """Shard every batch leaf's leading dim over the dp axes."""
    def spec(leaf):
        ndim = getattr(leaf, "ndim", 0)
        s = P(dp, *([None] * (ndim - 1))) if ndim >= 1 else P()
        return sanitize_spec(s, getattr(leaf, "shape", ()), mesh)
    return jax.tree_util.tree_map(spec, batch)


def to_shardings(specs, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_shardings(opt_state, params, mesh: Mesh) -> Any:
    """Optimizer-state leaves inherit the sharding of the matching param by
    SHAPE (moments are param-shaped; scalars/factored vectors replicate)."""
    pspecs = {tuple(l.shape): s for l, s in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(param_specs(params, mesh),
                                  is_leaf=lambda x: isinstance(x, P)))}

    def pick(leaf):
        spec = pspecs.get(tuple(getattr(leaf, "shape", ())), P())
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(pick, opt_state)
