"""Fault-tolerant training supervision: checkpoint-restart, heartbeats,
deterministic resume, elastic mesh changes.

On SPMD TPU pods the failure unit is the slice: a dead chip kills the whole
program, and recovery is restart-from-checkpoint (possibly on fewer pods).
This module provides the host-side machinery:

* ``Heartbeat`` — per-step timestamp file an external supervisor watches to
  detect hangs/stragglers (the in-band mitigation for data-parallel
  stragglers is architectural: the only cross-pod collective is one gradient
  reduce per step, so a slow pod delays one psum, not every layer).
* ``run_with_restarts`` — drives a step function, checkpoints every
  ``ckpt_every`` steps (async), and on failure restores the newest
  committed checkpoint and continues, up to ``max_failures``, with
  exponential backoff between restarts.  Errors are classified first
  (``repro.resilience.classify_error``): a *deterministic* failure — NaN
  loss, shape bug, assertion — raises immediately instead of burning every
  restart recomputing the same crash; unknown exceptions default to
  *transient* (a training step touches hosts, disks and interconnects, so
  retry-everything stays the backstop).  The data pipeline needs no
  replay: batch(i) is a pure function of i.
* Elastic restore: the restore path takes a shardings pytree for the CURRENT
  mesh, so a job checkpointed on 2 pods restarts cleanly on 1 (or 4).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.checkpoint import checkpoint as ckpt


class Heartbeat:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int, **info) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time(), **info}, f)
        os.replace(tmp, self.path)

    def age(self) -> Optional[float]:
        try:
            with open(self.path) as f:
                return time.time() - json.load(f)["time"]
        except (OSError, ValueError):
            return None


@dataclasses.dataclass
class RestartStats:
    failures: int = 0
    restarts_at: tuple = ()


def run_with_restarts(
    *,
    init_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Tuple[Any, Dict[str, float]]],
    ckpt_root: str,
    total_steps: int,
    ckpt_every: int = 50,
    max_failures: int = 3,
    heartbeat: Optional[Heartbeat] = None,
    state_shardings: Optional[Any] = None,
    on_metrics: Optional[Callable[[int, Dict[str, float]], None]] = None,
    backoff: float = 0.0,
    backoff_factor: float = 2.0,
    max_backoff: float = 30.0,
) -> Tuple[Any, RestartStats]:
    """Generic supervised train loop (see launch/train.py for the LM driver).

    ``step_fn(state, step)`` must be deterministic given (state, step) — the
    synthetic pipeline guarantees the data side of that contract.

    ``backoff`` > 0 sleeps before each restart, doubling (``backoff_factor``)
    per consecutive failure up to ``max_backoff`` — restarting full-tilt
    into a still-recovering slice just re-fails faster.
    """
    from repro.resilience import execute as _resil

    saver = ckpt.AsyncCheckpointer(ckpt_root)
    stats = RestartStats()

    def restore_or_init():
        last = ckpt.latest_step(ckpt_root)
        if last is None:
            return init_state(), 0
        state = init_state()
        state = ckpt.restore(ckpt_root, last, state, state_shardings,
                             allow_cast=True)
        return state, last + 1

    state, step = restore_or_init()
    while step < total_steps:
        try:
            state, metrics = step_fn(state, step)
            if heartbeat is not None:
                heartbeat.beat(step, **{k: float(v) for k, v in metrics.items()})
            if on_metrics is not None:
                on_metrics(step, metrics)
            if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                saver.save(step, state, extra={"metrics": {
                    k: float(v) for k, v in metrics.items()}})
            step += 1
        except Exception as exc:                             # noqa: BLE001
            # unknowns default to transient here: a real step touches
            # devices/disk/network, so only provably-deterministic failures
            # (NaN loss, shape bugs) skip the restart machinery
            kind = _resil.classify_error(exc, default=_resil.TRANSIENT)
            if kind == _resil.DETERMINISTIC:
                saver.wait()
                raise
            stats.failures += 1
            stats.restarts_at = stats.restarts_at + (step,)
            if stats.failures > max_failures:
                saver.wait()
                raise
            if backoff > 0.0:
                time.sleep(min(
                    backoff * backoff_factor ** (stats.failures - 1),
                    max_backoff))
            saver.wait()
            state, step = restore_or_init()
    saver.wait()
    return state, stats
