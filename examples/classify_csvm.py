"""Cascade-SVM text-style classification on a sparse ds-array (paper §6).

Builds a synthetic sparse "bag-of-topics" dataset (two classes, each loading
its own half of the vocabulary, ~85% zeros), loads it through scipy.sparse →
BCOO-blocked ds-array WITHOUT densifying, fits the CascadeSVM estimator and
reports accuracy + support-vector count + plan-cache behaviour — the sparse
workload the ds-array's CSR/BCOO block format exists for.

    PYTHONPATH=src python examples/classify_csvm.py
"""

import numpy as np

from repro.core import from_scipy, plan
from repro.estimators import CascadeSVM

rng = np.random.default_rng(0)
n_per, vocab = 200, 64

# class-specific topic loadings over a shared sparse background
docs = np.where(rng.random((2 * n_per, vocab)) < 0.92, 0.0,
                np.abs(rng.normal(size=(2 * n_per, vocab)))).astype(np.float32)
topic = ((rng.random((2 * n_per, vocab // 2)) < 0.25) *
         np.abs(rng.normal(size=(2 * n_per, vocab // 2))) * 4.0)
docs[:n_per, : vocab // 2] += topic[:n_per].astype(np.float32)
docs[n_per:, vocab // 2:] += topic[n_per:].astype(np.float32)
labels = np.concatenate([np.zeros(n_per), np.ones(n_per)]).astype(np.int32)
order = rng.permutation(2 * n_per)
docs, labels = docs[order], labels[order]

# the paper's loading path: scipy CSR -> BCOO-blocked ds-array, no densify
import scipy.sparse as ssp
x = from_scipy(ssp.csr_matrix(docs), (64, 32))
print(f"data: {x.shape} block_format={x.block_format} "
      f"density={np.count_nonzero(docs) / docs.size:.3f}")

plan.clear_cache()
svm = CascadeSVM(kernel="rbf", c=1.0, sv_cap=64, max_iter=5).fit(x, labels)
stats = plan.cache_stats()
acc = svm.score(x, labels)
print(f"CascadeSVM: acc={acc:.3f} n_sv={svm.n_sv_} "
      f"iters={svm.n_iter_} converged={svm.converged_}")
print(f"fit-loop plan cache: opt_runs={stats['opt_runs']} "
      f"opt_skips={stats['opt_skips']} compile_misses={stats['misses']} "
      f"hits={stats['hits']}")
assert acc >= 0.95, acc
assert stats["opt_runs"] == 1          # the recorded loop optimized ONCE

# held-out evaluation on a fresh draw from the same generator recipe
test = np.where(rng.random((100, vocab)) < 0.92, 0.0,
                np.abs(rng.normal(size=(100, vocab)))).astype(np.float32)
ttopic = ((rng.random((100, vocab // 2)) < 0.25) *
          np.abs(rng.normal(size=(100, vocab // 2))) * 4.0)
test[:50, : vocab // 2] += ttopic[:50].astype(np.float32)
test[50:, vocab // 2:] += ttopic[50:].astype(np.float32)
tl = np.concatenate([np.zeros(50), np.ones(50)]).astype(np.int32)
xt = from_scipy(ssp.csr_matrix(test), (64, 32))
print(f"holdout acc={svm.score(xt, tl):.3f}")
