"""ALS recommender on ds-arrays (paper §5.3, reduced-scale dense).

Builds a synthetic low-rank ratings matrix, factorizes it with the
distributed ALS estimator, and reports reconstruction error + top-items
for a user — the collaborative-filtering workflow the paper runs on the
Netflix data.

    PYTHONPATH=src python examples/recommender_als.py
"""

import numpy as np

from repro.algorithms import ALS
from repro.core import from_array

rng = np.random.default_rng(0)
n_items, n_users, rank = 300, 240, 6

# ground-truth preferences + noisy observed ratings
item_f = rng.normal(size=(n_items, rank)).astype(np.float32)
user_f = rng.normal(size=(n_users, rank)).astype(np.float32)
ratings = item_f @ user_f.T + 0.05 * rng.normal(size=(n_items, n_users)).astype(np.float32)

r = from_array(ratings, (64, 64))
als = ALS(n_factors=rank, reg=1e-2, max_iter=20, tol=1e-5).fit(r)

rec = np.asarray((als.u_ @ als.v_.transpose()).collect())
rmse = float(np.sqrt(((rec - ratings) ** 2).mean()))
print(f"ALS: rank={rank} iters={als.n_iter_} rmse={rmse:.4f}")
assert rmse < 0.1

user = 17
scores = rec[:, user]
top = np.argsort(-scores)[:5]
print(f"top-5 items for user {user}: {top.tolist()}")
print("truth ranking head:      ", np.argsort(-(item_f @ user_f[user]))[:5].tolist())
