"""Quickstart: the ds-array NumPy-like API (paper §4.2.3).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import Dataset, from_array, random_array
from repro.core import costmodel

print("== ds-array quickstart ==")

# create a blocked distributed array (blocks are the unit of distribution)
key = jax.random.PRNGKey(0)
x = random_array(key, shape=(1000, 400), block_shape=(250, 100))
print("x:", x)

# NumPy-like expressions run block-parallel (and through jax.jit):
w = x[100:400, :200]                       # indexing -> new ds-array
expr = (w.transpose().norm(axis=1) ** 2).sqrt()   # the paper's example
print("paper expression result shape:", expr.shape)

# matmul + reductions
gram = x.transpose() @ x                   # (400, 400), SUMMA under a mesh
col_mean = x.mean(axis=0)                  # paper Fig. 5 pattern
print("gram:", gram.shape, "col_mean:", col_mean.shape)

# compare with the Dataset (row-partitioned) baseline the paper replaces
data = np.asarray(x.collect())
ds = Dataset.from_array(data, 8)
t = ds.transpose()
print(f"Dataset transpose used {ds.counter.tasks} tasks "
      f"(law: N^2+N = {costmodel.dataset_transpose_tasks(8)}), "
      f"ds-array needs {costmodel.dsarray_transpose_tasks(8, 8)}")

np.testing.assert_allclose(np.asarray(x.T.collect()), t.collect(), atol=1e-5)
print("same result, two orders of magnitude fewer tasks at scale. done.")
