"""Cluster LM hidden states with distributed K-means — the ds-array data
plane composing with the LM framework (paper §5.5 + DESIGN.md §4).

Runs the qwen smoke model over synthetic batches, collects final hidden
states as a ds-array, and clusters them.

    PYTHONPATH=src python examples/activations_kmeans.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import KMeans
from repro.configs import get_smoke_config
from repro.core import from_array
from repro.data import PipelineConfig, SyntheticPipeline
from repro.models.model import build_model

cfg = get_smoke_config("qwen1.5-0.5b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
pipe = SyntheticPipeline(PipelineConfig(global_batch=8, seq_len=32,
                                        vocab_size=cfg.vocab_size))

hidden_fn = jax.jit(lambda p, t: model.module.forward_hidden(p, cfg, t)[0])
states = []
for step in range(4):
    batch = pipe.batch_at(step)
    h = hidden_fn(params, batch.tokens)          # (B, S, D)
    states.append(np.asarray(h).reshape(-1, cfg.d_model))
acts = np.concatenate(states)                     # (4*8*32, D)

x = from_array(acts, (256, cfg.d_model))          # ds-array of activations
km = KMeans(n_clusters=5, max_iter=25, seed=0).fit(x)
labels = np.asarray(km.predict(x).collect()).ravel()
sizes = np.bincount(labels, minlength=5)
print(f"clustered {acts.shape[0]} hidden states (d={cfg.d_model}) "
      f"into 5 groups, sizes={sizes.tolist()}, inertia={-km.score(x):.1f}")
assert sizes.sum() == acts.shape[0]
print("done.")
