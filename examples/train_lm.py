"""End-to-end LM training driver example: train a ~small model for a few
hundred steps with checkpoint-restart enabled, then greedy-decode from it.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(The same driver runs every assigned arch: --arch mamba2-370m etc.; on a pod
add --mesh data=16,model=16.)
"""

import argparse
import tempfile

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="qwen1.5-0.5b")
args = ap.parse_args()

with tempfile.TemporaryDirectory() as ckpt:
    train_mod.main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", ckpt, "--ckpt-every", "50",
    ])

print("\n== greedy decoding from a fresh model ==")
serve_mod.main(["--arch", args.arch, "--smoke", "--batch", "2",
                "--prompt-len", "8", "--gen", "16"])
